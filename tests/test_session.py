"""End-to-end KishuSession tests: undo, branch, merge/split, fault paths."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (FaultInjectedStore, KishuSession, MemoryStore,
                        OpaqueLeaf)


@pytest.fixture
def sess():
    s = KishuSession(MemoryStore(), chunk_bytes=1 << 12)

    def make_data(ns, n):
        rng = np.random.default_rng(ns["seed"])
        ns["data/x"] = rng.standard_normal((n, 8)).astype(np.float32)
        ns["data/step"] = 0

    def train(ns, steps):
        x, w, st = ns["data/x"], ns["model/w"], ns["data/step"]
        for _ in range(steps):
            w = w - 0.01 * (x.T @ (x @ w)) / len(x)
            st += 1
        ns["model/w"] = w
        ns["data/step"] = st

    s.register("make_data", make_data)
    s.register("train", train)
    s.init_state({"seed": 7, "model": {"w": np.ones((8, 4), np.float32)}})
    s.run("make_data", n=32)
    return s


def test_undo_exact(sess):
    c1 = sess.run("train", steps=3)
    w1 = sess.ns["model/w"].copy()
    sess.run("train", steps=4)
    st = sess.checkout(c1)
    assert np.array_equal(sess.ns["model/w"], w1)      # bit-exact (§5.3)
    assert st.covs_loaded >= 1 and st.covs_identical >= 1


def test_identical_covs_not_reloaded(sess):
    c1 = sess.run("train", steps=1)
    sess.run("train", steps=1)
    x_obj = sess.ns["data/x"]
    st = sess.checkout(c1)
    assert sess.ns["data/x"] is x_obj     # untouched object, not reloaded
    assert st.bytes_loaded < sess.ns["data/x"].nbytes + 1000


def test_branch_switching(sess):
    c1 = sess.run("train", steps=2)
    wa = sess.ns["model/w"].copy()
    sess.checkout(sess.graph.nodes[c1].parent)
    sess.run("train", steps=5)
    wb = sess.ns["model/w"].copy()
    assert not np.allclose(wa, wb)
    sess.checkout(c1)
    assert np.array_equal(sess.ns["model/w"], wa)


def test_jax_leaves_roundtrip():
    s = KishuSession(MemoryStore(), chunk_bytes=1 << 12)

    def bump(ns):
        ns["t"] = ns["t"] + 1.0
    s.register("bump", bump)
    s.init_state({"t": jnp.arange(8.0, dtype=jnp.bfloat16)})
    c1 = s.run("bump")
    v1 = np.asarray(s.ns["t"]).copy()
    s.run("bump")
    s.checkout(c1)
    assert isinstance(s.ns["t"], jax.Array)
    assert s.ns["t"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(s.ns["t"]), v1)


def test_prng_key_leaf_roundtrip():
    s = KishuSession(MemoryStore())

    def split(ns):
        k1, k2 = jax.random.split(jax.random.wrap_key_data(ns["rng"]))
        ns["rng"] = jax.random.key_data(k1)
        ns["draw"] = jax.random.normal(k2, (4,))
    s.register("split", split)
    s.init_state({"rng": jax.random.key_data(jax.random.key(0))})
    c1 = s.run("split")
    d1 = np.asarray(s.ns["draw"]).copy()
    s.run("split")
    s.checkout(c1)
    assert np.array_equal(np.asarray(s.ns["draw"]), d1)


def test_opaque_skip_and_replay():
    s = KishuSession(MemoryStore())

    def put(ns):
        ns["payload"] = int(ns["counter"])
        ns["gen"] = OpaqueLeaf(payload=int(ns["counter"]))

    def bump(ns):
        ns["counter"] = ns["counter"] + 1
        ns["gen"] = OpaqueLeaf(payload=int(ns["counter"]))

    s.register("put", put)
    s.register("bump", bump)
    s.init_state({"counter": 0})
    c1 = s.run("put")
    c2 = s.run("bump")          # gen updated -> new opaque at c2
    c3 = s.run("bump")
    st = s.checkout(c2)
    assert s.ns["gen"].payload == 1          # replayed bump at c2
    assert st.covs_recomputed >= 1


def test_chunk_loss_fallback(sess):
    c1 = sess.run("train", steps=2)
    w1 = sess.ns["model/w"].copy()
    sess.run("train", steps=1)
    man = sess.graph.manifest_of(("model/w",), c1)
    sess.store.delete_chunk(man["base"]["chunks"][0]["key"])
    # drop the shared chunk cache too: it would (correctly) mask the
    # storage incident; this test targets the replay fallback
    sess.chunk_cache.clear()
    sess.chunk_cache.max_bytes = 0
    sess.checkout(c1)
    assert np.allclose(sess.ns["model/w"], w1)
    assert sess.restorer.replays >= 1


def test_recursive_fallback():
    """Missing dependency of a missing co-variable: recursive replay."""
    store = MemoryStore()
    s = KishuSession(store, chunk_bytes=1 << 10)

    def stage1(ns):
        ns["a"] = np.full(2000, 1.0, np.float32)

    def stage2(ns):
        ns["b"] = ns["a"] * 2

    def stage3(ns):
        ns["c"] = ns["b"] + 1

    for n, f in [("s1", stage1), ("s2", stage2), ("s3", stage3)]:
        s.register(n, f)
    s.init_state({})
    c1 = s.run("s1")
    c2 = s.run("s2")
    c3 = s.run("s3")

    # corrupt b@c2 AND c@c3 -> restoring c requires replaying s3, whose dep b
    # must itself be replayed from a
    for key, ver in [(("b",), c2), (("c",), c3)]:
        man = s.graph.manifest_of(key, ver)
        for ch in man["base"]["chunks"]:
            store.delete_chunk(ch["key"])
    s.chunk_cache.clear()              # cache would mask the storage loss
    s.chunk_cache.max_bytes = 0
    # move away and delete things so checkout must load
    def clobber(ns):
        ns["b"] = np.zeros(1, np.float32)
        ns["c"] = np.zeros(1, np.float32)
    s.register("clobber", clobber)
    s.run("clobber")
    s.checkout(c3)
    assert float(s.ns["c"][0]) == 3.0
    assert s.restorer.replays >= 2


def test_check_all_mode_equivalent_delta():
    """AblatedKishu(check-all) must find the same updates, just slower."""
    for check_all in (False, True):
        s = KishuSession(MemoryStore(), check_all=check_all)

        def touch_one(ns):
            ns["a"] = ns["a"] + 1
        s.register("touch_one", touch_one)
        s.init_state({"a": np.zeros(4, np.float32),
                      "b": np.ones(4, np.float32)})
        s.run("touch_one")
        assert s.last_run.covs_updated == 1
        if check_all:
            assert s.last_run.covs_skipped == 0
        else:
            assert s.last_run.covs_skipped >= 1


def test_graph_scales_and_diff_fast(sess):
    import time
    cids = [sess.run("train", steps=1) for _ in range(50)]
    t0 = time.perf_counter()
    plan = sess.graph.diff(cids[-1], cids[0])
    dt = time.perf_counter() - t0
    assert dt < 0.1
    assert plan.n_diverged >= 1
