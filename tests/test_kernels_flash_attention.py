"""Flash-attention Pallas kernel vs naive oracle: shape/GQA/block sweeps in
interpret mode, plus equivalence with the model's attention core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


from repro.kernels.flash_attention import flash_attention

pytestmark = pytest.mark.slow    # JAX jit-heavy; fast lane: -m "not slow"

CASES = [
    # B, S, Hq, Hkv, hd, bq, bk, causal
    (2, 128, 4, 2, 64, 32, 32, True),
    (1, 256, 8, 8, 32, 64, 128, True),
    (2, 64, 6, 2, 16, 64, 64, False),
    (1, 512, 2, 1, 128, 128, 64, True),
    (1, 64, 15, 5, 64, 64, 64, True),      # smollm-style head counts
]


@pytest.mark.parametrize("case", CASES)
def test_flash_matches_reference(case):
    b, s, hq, hkv, hd, bq, bk, causal = case
    ks = jax.random.split(jax.random.key(sum(case)), 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    want = flash_attention(q, k, v, causal=causal, backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=1e-4)


def test_flash_matches_model_attention_core():
    from repro.models.layers import attention_core
    ks = jax.random.split(jax.random.key(9), 3)
    b, s, hq, hkv, hd = 2, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    want = attention_core(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=1e-4)


def test_flash_bf16():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    want = flash_attention(q, k, v, backend="ref")
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want, dtype=np.float32),
                               atol=3e-2, rtol=3e-2)


def test_first_row_attends_only_itself():
    """Causal row 0 must equal v[0] exactly (online softmax edge case)."""
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (1, 64, 1, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 1, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 1, 16), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out[0, 0, 0]),
                               np.asarray(v[0, 0, 0]), atol=1e-5)
