"""Multi-session safety layer: writer leases, tenant namespaces,
refcounted cross-session GC, byte quotas, the kishud daemon and its CLI
verbs (DESIGN.md §14).

The crash-interleaving sweeps live in test_txn_crash.py; this suite pins
the unit-level contracts each of those sweeps relies on.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import txn
from repro.core.chunkstore import (MemoryStore, NamespacedStore, open_store,
                                   tenant_ids, validate_tenant_id)
from repro.core.graph import REFS_DOC, ChunkRefCounts
from repro.core.lease import (Lease, LeaseHeld, LeaseLost, lease_status)
from repro.core.session import KishuSession, QuotaExceededError
from repro.launch.kishu_cli import main as cli
from repro.launch.kishud import (BACKGROUND, INTERACTIVE, AdmissionQueue,
                                 Kishud, KishudServer, control)


def set_val(ns, name, val):
    ns[name] = np.full(400, float(val), np.float32)


def build_session(store, **kw):
    s = KishuSession(store, chunk_bytes=1 << 9, **kw)
    s.register("set_val", set_val)
    return s


# ---------------------------------------------------------------------------
# leases
# ---------------------------------------------------------------------------

def test_lease_acquire_release_cycle():
    store = MemoryStore()
    a = Lease(store, ttl_s=5.0).acquire()
    assert a.held and a.token == 1
    assert lease_status(store)[0]["owner"] == a.owner
    a.release()
    assert not a.held and store.get_meta("lease/writer") is None
    # a clean release removes the doc, so the next grant starts a fresh
    # token chain — fencing only needs monotonicity while a doc exists
    b = Lease(store, ttl_s=5.0).acquire()
    assert b.held and b.token == 1


def test_lease_contender_refused_then_steals_after_observed_ttl():
    store = MemoryStore()
    ttl = 0.2
    a = Lease(store, ttl_s=ttl).acquire()
    contender = Lease(store, ttl_s=ttl)
    with pytest.raises(LeaseHeld):
        contender.acquire(wait_s=0.0)      # holder alive: refused at once
    t0 = time.monotonic()
    contender.acquire(wait_s=ttl * 20, poll_s=0.01)
    waited = time.monotonic() - t0
    assert waited >= ttl, f"stole after only {waited:.3f}s"
    assert contender.token == a.token + 1  # fenced takeover


def test_lease_doc_age_is_never_trusted():
    """A lease doc with an ancient wall-clock ``ts`` (the holder's clock
    stepped, or it simply uses another timezone) must still cost a full
    observed TTL — expiry is observation-based, never doc-declared."""
    store = MemoryStore()
    store.put_meta("lease/writer", {"owner": "ghost", "token": 3,
                                    "ttl_s": 0.2, "ts": 0.0})
    with pytest.raises(LeaseHeld):
        Lease(store, ttl_s=0.2).acquire(wait_s=0.0)
    t0 = time.monotonic()
    Lease(store, ttl_s=0.2).acquire(wait_s=5.0, poll_s=0.01)
    assert time.monotonic() - t0 >= 0.2


def test_lease_renew_detects_takeover_and_release_spares_thief():
    store = MemoryStore()
    a = Lease(store, ttl_s=5.0).acquire()
    thief = Lease(store, ttl_s=5.0).acquire(steal=True)  # operator override
    with pytest.raises(LeaseLost):
        a.renew()
    a.release()                  # deposed: must NOT delete the thief's doc
    doc = store.get_meta("lease/writer")
    assert doc["owner"] == thief.owner and doc["token"] == thief.token


def test_lease_local_expiry_refuses_publish():
    """ensure() past the local horizon raises — the holder would rather
    stop than publish a commit a contender may already have overwritten."""
    store = MemoryStore()
    a = Lease(store, ttl_s=0.05).acquire()
    time.sleep(0.1)
    with pytest.raises(LeaseLost):
        a.ensure()
    assert not a.held


def test_session_publish_fenced_after_steal():
    """End to end: a session whose lease is stolen must refuse its next
    commit (TxnError from the publish guard), leaving the thief's graph
    untouched and the store fsck-clean."""
    from repro.core.txn import TxnError

    store = MemoryStore()
    s = build_session(store, tenant="nb", lease_ttl_s=0.15)
    s.init_state({"a": np.arange(64, dtype=np.float32)})
    good = s.head
    # operator steals the lease out from under the live session
    Lease(NamespacedStore(store, "nb"), ttl_s=5.0).acquire(steal=True)
    time.sleep(0.2)              # past the holder's local horizon
    with pytest.raises(TxnError):
        s.run("set_val", name="x", val=1)
    view = NamespacedStore(store, "nb")
    assert view.get_meta("HEAD")["head"] == good
    assert txn.fsck(view).problems == 0


# ---------------------------------------------------------------------------
# tenant namespaces
# ---------------------------------------------------------------------------

def test_namespace_isolation_with_chunk_dedup():
    store = MemoryStore()
    sessions = {}
    for name in ("alice", "bob"):
        s = build_session(store, tenant=name)
        s.init_state({"a": np.arange(64, dtype=np.float32)})
        s.run("set_val", name="x", val=1)   # identical content per tenant
        sessions[name] = s
    assert sorted(tenant_ids(store)) == ["alice", "bob"]
    heads = {n: s.head for n, s in sessions.items()}
    # metadata is disjoint: each namespace sees only its own graph
    for name, s in sessions.items():
        assert sorted(s.graph.nodes) == sorted(
            n.split("/")[-1] for n in
            NamespacedStore(store, name).list_meta("commit/"))
    # chunks are shared: identical content deduped store-wide
    one = build_session(MemoryStore())
    one.init_state({"a": np.arange(64, dtype=np.float32)})
    one.run("set_val", name="x", val=1)
    assert store.n_chunks() == one.store.n_chunks()
    for s in (*sessions.values(), one):
        s.close()
    assert heads["alice"] == heads["bob"]   # same workload, same ids


def test_open_store_tenant_param():
    s = open_store("memory://?tenant=alice")
    assert isinstance(s, NamespacedStore) and s.meta_prefix == "tenant/alice/"
    with pytest.raises(ValueError):
        open_store("memory://?tenant=no/slashes")
    with pytest.raises(ValueError):
        validate_tenant_id("under_score")   # DirectoryStore maps _ specially
    with pytest.raises(ValueError):
        open_store("memory://?frobnicate=1")


def test_cross_tenant_gc_respects_shared_chunks():
    """alice and bob commit identical content (fully deduped); alice
    deleting her branch and gc'ing must reap nothing while bob still
    references the chunks — and bob's later gc reaps them for real."""
    store = MemoryStore()
    a = build_session(store, tenant="alice")
    b = build_session(store, tenant="bob")
    for s in (a, b):
        s.init_state({"a": np.arange(64, dtype=np.float32)})
        root = s.run("set_val", name="keep", val=1)
        s.run("set_val", name="drop", val=2)
        tip = s.head
        s.checkout(root)
        s.run("set_val", name="keep2", val=3)
        s._doomed = tip                      # branch to delete later
    n_before = store.n_chunks()
    assert a.delete_branch(a._doomed)
    out = a.gc()
    assert out["chunks_dropped"] == 0, \
        "alice reaped chunks bob's identical branch still references"
    assert store.n_chunks() == n_before
    assert b.delete_branch(b._doomed)
    out = b.gc()
    assert out["chunks_dropped"] > 0         # last reference gone: reap
    for s in (a, b):
        s.close()
    for tid, rep in txn.fsck_all(store).items():
        assert rep.problems == 0, (tid, rep.details)


def test_refcount_ledger_matches_commit_walk():
    store = MemoryStore()
    s = build_session(store)
    s.init_state({"a": np.arange(64, dtype=np.float32)})
    c1 = s.run("set_val", name="x", val=1)
    s.run("set_val", name="y", val=2)
    tip = s.head
    s.checkout(c1)
    s.run("set_val", name="y", val=7)
    s.delete_branch(tip)
    rebuilt = ChunkRefCounts.from_nodes(s.graph.nodes)
    assert s.graph.refs.counts == rebuilt.counts
    assert txn.fsck(store).refs_drift == 0
    # the ledger survives a reload and a gc
    s.gc()
    s.close()
    s2 = KishuSession(store, chunk_bytes=1 << 9)
    assert s2.graph.refs.counts == \
        ChunkRefCounts.from_nodes(s2.graph.nodes).counts
    s2.close()


def test_quota_blocks_commit_before_publish():
    store = MemoryStore()
    s = build_session(store, tenant="t", quota_bytes=1000)
    s.init_state({"a": np.arange(64, dtype=np.float32)})   # 256 B referenced
    # a constant-valued array dedups to ~2 unique chunks (~576 B logical)
    good = s.run("set_val", name="x", val=1)
    with pytest.raises(QuotaExceededError):
        s.run("set_val", name="y", val=2)                  # would cross 1000
    assert s.head == good                    # refused commit left no trace
    assert s.storage_stats()["tenant_ref_bytes"] <= 1000
    s.close()
    view = NamespacedStore(store, "t")
    assert txn.fsck(view).problems == 0


# ---------------------------------------------------------------------------
# kishud: admission queue, daemon, control socket
# ---------------------------------------------------------------------------

def test_admission_queue_interactive_before_background():
    q = AdmissionQueue(workers=1)
    order = []
    gate = threading.Event()
    blocker = q.submit(gate.wait)            # pins the only worker
    jb = q.submit(lambda: order.append("bg"), BACKGROUND)
    ji = q.submit(lambda: order.append("int"), INTERACTIVE)
    gate.set()
    ji.done.wait(5)
    jb.done.wait(5)
    blocker.done.wait(5)
    assert order == ["int", "bg"], \
        "background work was admitted ahead of interactive work"
    stats = q.stats()
    assert stats["served_interactive"] == 2    # blocker + ji
    assert stats["served_background"] == 1
    q.close()


def test_admission_queue_delivers_exceptions():
    q = AdmissionQueue(workers=1)
    with pytest.raises(ZeroDivisionError):
        q.run(lambda: 1 // 0)
    assert q.run(lambda: 41 + 1) == 42       # worker survived the raise
    q.close()


def test_kishud_multiplexes_tenants_with_shared_cache():
    d = Kishud(MemoryStore(), workers=2, lease_ttl_s=30.0,
               chunk_bytes=1 << 9)
    a = d.session("alice")
    b = d.session("bob")
    for s in (a, b):
        s.register("set_val", set_val)
        s.init_state({"a": np.arange(64, dtype=np.float32)})
    ca = a.run("set_val", name="x", val=1)
    cb = b.run("set_val", name="x", val=2)
    a.checkout(ca)
    b.checkout(cb)
    assert np.all(a.ns["x"] == 1.0) and np.all(b.ns["x"] == 2.0)
    st = d.status()
    assert st["n_sessions"] == 2 and st["tenants"] == ["alice", "bob"]
    assert st["queue"]["served_interactive"] >= 6
    rows = {r["tenant"]: r for r in d.tenants()}
    assert rows["alice"]["lease_owner"] != rows["bob"]["lease_owner"]
    assert rows["alice"]["n_commits"] == rows["bob"]["n_commits"] == 3
    d.close()


def test_kishud_session_survives_daemon_restart(tmp_path):
    uri = f"dir://{tmp_path}/cas"
    d = Kishud(uri, workers=1, lease_ttl_s=0.2, chunk_bytes=1 << 9)
    s = d.session("nb")
    s.register("set_val", set_val)
    s.init_state({"a": np.arange(64, dtype=np.float32)})
    cid = s.run("set_val", name="x", val=4)
    d.queue.close()              # simulated daemon death: no session close
    del d, s
    d2 = Kishud(uri, workers=1, lease_ttl_s=0.2, chunk_bytes=1 << 9)
    t0 = time.monotonic()
    s2 = d2.session("nb", lease_wait_s=10.0)   # steal after observed TTL
    assert time.monotonic() - t0 >= 0.2
    s2.register("set_val", set_val)
    assert s2.head == cid
    # a fresh session attaches with an empty live namespace: rehydrate
    s2.session.loader.materialize_state(s2.session.tracked, cid)
    assert np.all(s2.ns["x"] == 4.0)
    d2.close()


def test_kishud_socket_control(tmp_path):
    d = Kishud(MemoryStore(), workers=1, lease_ttl_s=30.0,
               chunk_bytes=1 << 9)
    sock = str(tmp_path / "kd.sock")
    srv = KishudServer(d, sock)
    try:
        assert control(sock, "ping")["pong"] is True
        s = d.session("alice")
        s.register("set_val", set_val)
        s.init_state({"a": np.arange(64, dtype=np.float32)})
        st = control(sock, "status")
        assert st["ok"] and st["tenants"] == ["alice"]
        tn = control(sock, "tenants")
        assert tn["tenants"][0]["tenant"] == "alice"
        assert tn["leases"][0]["owner"] is not None
        assert control(sock, "frobnicate")["ok"] is False
        assert control(sock, "stop")["stopping"] is True
        assert srv.wait(5)
    finally:
        srv.close()
        d.close()


# ---------------------------------------------------------------------------
# CLI verbs
# ---------------------------------------------------------------------------

def test_cli_lease_and_tenants_verbs(tmp_path, capsys):
    uri = f"dir://{tmp_path}/cas"
    store = open_store(uri)
    a = build_session(store, tenant="alice", lease_ttl_s=60.0)
    a.init_state({"a": np.arange(64, dtype=np.float32)})
    b = build_session(store, tenant="bob")
    b.init_state({"a": np.arange(64, dtype=np.float32)})
    b.close()

    assert cli(["--store", uri, "tenants"]) == 0
    out = capsys.readouterr().out
    assert "alice" in out and "bob" in out

    assert cli(["--store", f"{uri}?tenant=alice", "lease"]) == 0
    out = capsys.readouterr().out
    assert a.lease.owner in out
    assert cli(["--store", f"{uri}?tenant=alice", "lease",
                "--release", "writer"]) == 0
    capsys.readouterr()
    assert NamespacedStore(store, "alice").get_meta("lease/writer") is None
    a.close()                    # release of the already-dropped doc: no-op
