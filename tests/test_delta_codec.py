"""On-device bit-plane codec: host codec contract, device↔host parity,
frame/store interop, probe heuristics, and writer plumbing (fast lane).

The load-bearing guarantee: a chunk encoded ON DEVICE (bitshuffle + RLE
masks, Pallas kernel exercised via the interpreter) decodes to the exact
logical bytes through the plain numpy host decoder, on every dtype, odd
shape and tail length — and a store holding a mix of raw, zlib-framed and
bshuf-framed chunks under the same logical CAS keys reads back
transparently on every backend.
"""
import numpy as np
import pytest

from repro.core.chunkstore import (CompressedStore, DirectoryStore,
                                   MemoryStore, SQLiteStore, chunk_key,
                                   decode_chunk, encode_chunk,
                                   resolve_codec)
from repro.kernels.delta_codec import host as H
from repro.kernels.delta_codec import ops as codec_ops
from _hypothesis_compat import (HAVE_HYPOTHESIS, HealthCheck, given,
                                settings, st)

BACKENDS = [("ref", {}), ("pallas", {"interpret": True})]


# ---------------------------------------------------------------- host codec

@pytest.mark.parametrize("n", [0, 1, 3, 4, 127, 128, 1024, 4096, 4097])
def test_host_roundtrip_sizes(n):
    rng = np.random.default_rng(n)
    for data in (bytes(rng.integers(0, 256, n, dtype=np.uint8)),
                 (np.arange(-(-n // 4) or 1, dtype=np.uint32) % 97)
                 .tobytes()[:n]):
        payload = H.bitplane_compress(data)
        assert H.bitplane_decompress(payload) == data


def test_host_compresses_small_values():
    """Values < 2**7 leave 25 of 32 bit-planes constant: the stream must
    come out well under half the raw size."""
    data = (np.arange(4096, dtype=np.uint32) % 97).tobytes()
    payload = H.bitplane_compress(data)
    assert len(payload) < len(data) // 2
    assert H.bitplane_decompress(payload) == data


def test_decompress_rejects_corrupt():
    data = (np.arange(256, dtype=np.uint32) % 17).tobytes()
    payload = bytearray(H.bitplane_compress(data))
    with pytest.raises(ValueError):
        H.bitplane_decompress(bytes(payload[:-1]))     # truncated
    payload[0] = 9                                     # bad version
    with pytest.raises(ValueError):
        H.bitplane_decompress(bytes(payload))
    with pytest.raises(ValueError):
        H.bitplane_decompress(b"")


@settings(max_examples=60, deadline=None,
          suppress_health_check=list(HealthCheck) if HAVE_HYPOTHESIS else [])
@given(st.binary(min_size=0, max_size=2048))
def test_host_roundtrip_property(data):
    assert H.bitplane_decompress(H.bitplane_compress(data)) == data


# ----------------------------------------------- device encode ↔ host decode

_DTYPES = ["uint8", "int8", "bool", "uint16", "int16", "float16",
           "uint32", "int32", "float32", "uint64", "int64", "float64",
           "complex64", "complex128"]
_SHAPES = [(0,), (1,), (7,), (33,), (5, 13), (256,), (3, 4, 5)]


def _chunk_rows(data: bytes, chunk_bytes: int):
    """Split logical bytes into word-padded [R, W] uint32 rows + lengths,
    the shape the delta pipeline hands the device encoder."""
    lens, blobs = [], []
    for lo in range(0, len(data), chunk_bytes):
        blob = data[lo:lo + chunk_bytes]
        lens.append(len(blob))
        blobs.append(blob + b"\0" * (chunk_bytes - len(blob)))
    rows = (np.frombuffer(b"".join(blobs), np.uint8)
            .reshape(len(blobs), chunk_bytes).view("<u4"))
    return rows, lens


@pytest.mark.parametrize("backend,kw", BACKENDS)
@pytest.mark.parametrize("dtype", _DTYPES)
@pytest.mark.parametrize("shape", _SHAPES)
def test_device_encode_host_decode_every_dtype(backend, kw, dtype, shape):
    """Property: device encode (incl. the Pallas kernel in interpret mode)
    ↔ host numpy decode is byte-exact for every dtype / odd shape / empty
    chunk, including word-padded tails truncated by raw_len."""
    import jax.numpy as jnp

    rng = np.random.default_rng(hash((dtype, shape)) % 2**32)
    n = int(np.prod(shape))
    raw = rng.integers(0, 256, max(n, 1) * np.dtype(dtype).itemsize,
                       dtype=np.uint8)
    data = np.frombuffer(raw.tobytes(), dtype=dtype, count=n) \
        .reshape(shape).tobytes()
    cb = 128                              # MIN_GROUP_WORDS words
    if not data:                          # empty chunk: host framing only
        assert H.bitplane_decompress(H.bitplane_compress(data)) == data
        return
    rows, lens = _chunk_rows(data, cb)
    masks, planes_d, gw = codec_ops.encode_rows(
        jnp.asarray(rows), backend=backend, **kw)
    frames = H.frames_from_encoded(masks, np.asarray(planes_d),
                                   rows.shape[1] // gw, gw, lens)
    got = b"".join(H.bitplane_decompress(f[H._FRAME_HDR:]) for f in frames)
    assert got == data


@pytest.mark.parametrize("backend,kw", BACKENDS)
def test_device_matches_host_stream(backend, kw):
    """The device payload must be byte-identical to the host reference
    codec at the same group size — the CAS frame is the contract."""
    import jax.numpy as jnp

    rows = (np.arange(8 * 256, dtype=np.uint32) % 251).reshape(8, 256)
    masks, planes_d, gw = codec_ops.encode_rows(
        jnp.asarray(rows), backend=backend, **kw)
    frames = H.frames_from_encoded(masks, np.asarray(planes_d),
                                   256 // gw, gw, [1024] * 8)
    for i in range(8):
        want = H.bitplane_compress(rows[i].tobytes(), group_words=gw)
        assert frames[i][H._FRAME_HDR:] == want


def test_encode_rows_rejects_narrow_rows():
    import jax.numpy as jnp
    with pytest.raises(ValueError):
        codec_ops.encode_rows(jnp.zeros((4, 16), jnp.uint32), backend="ref")


# ------------------------------------------------------------------- probes

def test_probe_heuristics():
    compressible = (np.arange(4096, dtype=np.uint32) % 97).tobytes()
    random = bytes(np.random.default_rng(0)
                   .integers(0, 256, 4096, dtype=np.uint8))
    assert H.bitplane_probe(compressible)
    assert not H.bitplane_probe(random)
    assert not H.bitplane_probe(b"x" * (H.PROBE_MIN_BYTES - 1))


def test_probe_device_rows():
    import jax.numpy as jnp
    good = jnp.asarray((np.arange(4 * 256, dtype=np.uint32) % 97)
                       .reshape(4, 256))
    bad = jnp.asarray(np.random.default_rng(1)
                      .integers(0, 2**32, (4, 256), dtype=np.uint64)
                      .astype(np.uint32))
    assert codec_ops.probe_device_rows(good)
    assert not codec_ops.probe_device_rows(bad)
    assert not codec_ops.probe_device_rows(jnp.zeros((0, 256), jnp.uint32))


# ----------------------------------------------------------- store interop

def _stores(tmp_path):
    from repro.core.fabric import ReplicatedStore, ShardedStore, TieredStore
    yield "memory", MemoryStore()
    yield "dir", DirectoryStore(str(tmp_path / "dir"))
    yield "sqlite", SQLiteStore(str(tmp_path / "cas.db"))
    yield "fabric", ShardedStore([MemoryStore() for _ in range(3)])
    yield "tiered", TieredStore(SQLiteStore(str(tmp_path / "cold.db")))
    yield "replica", ReplicatedStore([MemoryStore(), MemoryStore()])


def test_mixed_raw_and_framed_reads(tmp_path):
    """One store holding raw, zlib-framed and device-bshuf-framed chunks
    under logical CAS keys must read all of them back as logical bytes on
    every backend — CLI / loader / fabric paths never special-case."""
    logical = {
        "comp": (np.arange(1024, dtype=np.uint32) % 89).tobytes(),
        "rand": bytes(np.random.default_rng(2)
                      .integers(0, 256, 4096, dtype=np.uint8)),
        "tiny": b"hello chunks",
    }
    zlib_codec = resolve_codec("zlib")
    for name, store in _stores(tmp_path):
        keys = {}
        for tag, data in logical.items():
            k = chunk_key(data)
            keys[tag] = k
            if tag == "comp":     # device-encoded bshuf frame, stored put
                frame = H.make_frame(H.bitplane_compress(data), len(data))
                assert frame[:4] == H.FRAME_MAGIC
                store.put_chunk_stored(k, frame)
            elif tag == "rand":   # host zlib framing (may stay raw)
                store.put_chunk_stored(k, encode_chunk(data, zlib_codec))
            else:                 # plain raw put
                store.put_chunk(k, data)
        for tag, data in logical.items():
            assert store.get_chunk(keys[tag]) == data, (name, tag)
        got = store.get_chunks(list(keys.values()))
        assert got == {keys[t]: d for t, d in logical.items()}, name


def test_stored_put_does_not_double_frame():
    inner = MemoryStore()
    store = CompressedStore(inner, codec="zlib")
    data = (np.arange(2048, dtype=np.uint32) % 97).tobytes()
    frame = H.make_frame(H.bitplane_compress(data), len(data))
    k = chunk_key(data)
    store.put_chunks_stored([(k, frame)])
    assert inner.chunks[k] == frame           # bit-exact, no re-framing
    assert store.get_chunk(k) == data
    assert store.stored_put_bytes == len(frame)


def test_compressed_store_probe_veto_counts():
    store = CompressedStore(MemoryStore(), codec="bshuf")
    rnd = bytes(np.random.default_rng(3)
                .integers(0, 256, 4096, dtype=np.uint8))
    store.put_chunk(chunk_key(rnd), rnd)
    assert store.chunks_codec_skipped == 1
    comp = (np.arange(1024, dtype=np.uint32) % 89).tobytes()
    store.put_chunk(chunk_key(comp), comp)
    assert store.chunks_codec_skipped == 1
    assert store.get_chunk(chunk_key(comp)) == comp
    assert store.stored_put_bytes < store.logical_put_bytes


def test_bshuf_codec_registered():
    codec = resolve_codec("bshuf")
    assert codec is not None and codec.codec_id == H.CODEC_ID
    data = (np.arange(512, dtype=np.uint32) % 53).tobytes()
    enc = encode_chunk(data, codec)
    assert enc[:4] == H.FRAME_MAGIC and len(enc) < len(data)
    assert decode_chunk(enc) == data


# ------------------------------------------------------- pipeline plumbing

def _mk_pack(nbytes, cb, dirty, *, compressible=True, seed=0):
    import jax.numpy as jnp

    from repro.core import hashing
    from repro.kernels.delta_pack.ops import delta_pack

    rng = np.random.default_rng(seed)
    if compressible:
        a = ((np.arange(-(-nbytes // 4), dtype=np.uint32) % 97)
             .tobytes()[:nbytes])
        a = np.frombuffer(a, np.uint8).copy()
    else:
        a = rng.integers(0, 256, nbytes, dtype=np.uint8)
    prev = hashing.chunk_hashes_np(a.tobytes(), cb)
    b = a.copy()
    for i in dirty:
        b[i * cb] ^= 0x01
    return delta_pack(jnp.asarray(b), prev, cb, backend="ref"), b


def test_read_chunks_encoded_frames_and_counters():
    pack, b = _mk_pack(4096 * 16, 4096, [1, 5, 9], compressible=True)
    out = list(pack.read_chunks_encoded())
    assert [ci for ci, _, _ in out] == [1, 5, 9]
    assert pack.codec_chunks_encoded == 3 and pack.codec_chunks_skipped == 0
    for ci, logical, frame in out:
        lo = ci * 4096
        assert logical == b[lo:lo + 4096].tobytes()
        assert frame is not None and frame[:4] == H.FRAME_MAGIC
        assert decode_chunk(frame) == logical
        assert len(frame) < len(logical)


def test_read_chunks_encoded_probe_veto_and_env_gate(monkeypatch):
    pack, _ = _mk_pack(4096 * 8, 4096, [2, 6], compressible=False, seed=5)
    out = list(pack.read_chunks_encoded())
    assert all(frame is None for _, _, frame in out)
    assert pack.codec_chunks_skipped == 2 and pack.codec_chunks_encoded == 0

    monkeypatch.setenv("KISHU_DEVICE_CODEC", "0")
    pack2, _ = _mk_pack(4096 * 8, 4096, [2, 6], compressible=True)
    out2 = list(pack2.read_chunks_encoded())
    assert all(frame is None for _, _, frame in out2)
    assert pack2.codec_chunks_skipped == 2


def test_session_write_stats_surface_codec(tmp_path, monkeypatch):
    """chunks_encoded / chunks_codec_skipped / bytes_dev2host must surface
    in WriteStats and in the persisted commit stats the CLI aggregates."""
    import jax.numpy as jnp

    from repro.core import KishuSession

    monkeypatch.setenv("KISHU_DEVICE_DELTA", "1")
    monkeypatch.setenv("KISHU_DEVICE_HASH", "1")
    monkeypatch.setenv("KISHU_DEVICE_CODEC", "1")
    store = MemoryStore()
    sess = KishuSession(store, chunk_bytes=4096, cache_bytes=0)

    def init(ns):
        ns["v"] = jnp.arange(1 << 14, dtype=jnp.int32) % 97

    def mutate(ns):
        ns["v"] = ns["v"].at[jnp.arange(4) * 1024].set(7)

    sess.register("init", init)
    sess.register("mutate", mutate)
    sess.init_state({})
    sess.run("init")
    cid = sess.run("mutate")
    w = sess.last_run.write
    assert w.chunks_encoded > 0
    assert w.bytes_dev2host > 0
    node = sess.graph.nodes[cid]
    assert node.stats["chunks_encoded"] == w.chunks_encoded
    assert node.stats["bytes_dev2host"] == w.bytes_dev2host
    assert "chunks_codec_skipped" in node.stats

    # the store holds *frames* for the encoded chunks, under logical keys
    framed = [k for k in store.list_chunk_keys()
              if store.chunks[k][:4] == H.FRAME_MAGIC]
    assert framed
    for k in framed:
        assert chunk_key(store.get_chunk(k)) == k
    sess.close()
