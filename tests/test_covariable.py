"""Co-variable detection tests (Defs 1-3, Lemma 1)."""
import numpy as np
import pytest

from repro.core.covariable import (RecordBuilder, cov_key, detect_delta,
                                   group_covariables)
from repro.core.namespace import Namespace, TrackedNamespace
from repro.core.serialize import OpaqueLeaf


def build_all(ns, builder=None):
    builder = builder or RecordBuilder(chunk_bytes=1 << 12)
    cache = {}
    return {n: builder.build(n, ns[n], cache) for n in ns.names()}


def test_alias_groups_share_covariable():
    ns = Namespace()
    w = np.ones((4, 4), np.float32)
    ns["a"] = w
    ns["b"] = w                       # same buffer
    ns["c"] = w.copy()                # equal values, different buffer
    covs = group_covariables(build_all(ns))
    assert cov_key(["a", "b"]) in covs
    assert cov_key(["c"]) in covs


def test_numpy_views_form_covariable():
    ns = Namespace()
    base = np.arange(100, dtype=np.float32)
    ns["x"] = base[:50]
    ns["y"] = base[50:]
    ns["z"] = np.arange(7.0)
    covs = group_covariables(build_all(ns))
    assert cov_key(["x", "y"]) in covs
    assert cov_key(["z"]) in covs


def _detect(ns, tracked, records, covs):
    accessed = set(tracked.accessed) | set(tracked.written) | set(tracked.deleted)
    return detect_delta(records, covs, ns, accessed,
                        RecordBuilder(chunk_bytes=1 << 12))


def test_lemma1_pruning_and_no_false_negative():
    ns = Namespace()
    ns["p"] = np.zeros(10, np.float32)
    ns["q"] = np.ones(10, np.float32)
    ns["r"] = np.full(10, 2.0, np.float32)
    records = build_all(ns)
    covs = group_covariables(records)

    t = TrackedNamespace(ns)
    t["p"] = t["p"] + 1               # touch p only
    delta, new_records = _detect(ns, t, records, covs)
    assert cov_key(["p"]) in delta.updated
    assert delta.skipped == 2          # q, r pruned without inspection
    assert cov_key(["q"]) not in delta.updated


def test_access_without_change_is_not_update():
    ns = Namespace()
    ns["p"] = np.zeros(10, np.float32)
    records = build_all(ns)
    covs = group_covariables(records)
    t = TrackedNamespace(ns)
    _ = t["p"]                         # read only
    t["p"] = ns["p"]                   # write-back same object
    delta, _ = _detect(ns, t, records, covs)
    assert not delta.updated
    assert cov_key(["p"]) in delta.unchanged_accessed


def test_rebind_same_values_not_update():
    """Functional updates create new arrays; unchanged *values* must not be
    flagged (our hash compare improves on the paper's address compare)."""
    ns = Namespace()
    ns["p"] = np.arange(10, dtype=np.float32)
    records = build_all(ns)
    covs = group_covariables(records)
    t = TrackedNamespace(ns)
    t["p"] = ns["p"].copy()            # new buffer, same content
    delta, _ = _detect(ns, t, records, covs)
    assert not delta.updated


def test_split_and_merge():
    ns = Namespace()
    w = np.ones(8, np.float32)
    ns["a"] = w
    ns["b"] = w
    records = build_all(ns)
    covs = group_covariables(records)
    # split: b becomes independent
    t = TrackedNamespace(ns)
    t["b"] = w.copy()
    delta, records = _detect(ns, t, records, covs)
    assert cov_key(["a", "b"]) in delta.deleted
    assert cov_key(["a"]) in delta.updated and cov_key(["b"]) in delta.updated
    covs = group_covariables(records)
    # merge: retie
    t = TrackedNamespace(ns)
    t["b"] = t["a"]
    delta, records = _detect(ns, t, records, covs)
    assert cov_key(["a", "b"]) in delta.updated
    assert cov_key(["a"]) in delta.deleted and cov_key(["b"]) in delta.deleted


def test_structure_change_is_update():
    ns = Namespace()
    ns["p"] = np.zeros((4, 4), np.float32)
    records = build_all(ns)
    covs = group_covariables(records)
    t = TrackedNamespace(ns)
    t["p"] = np.zeros((4, 4), np.float64)   # dtype change, same bytes? no — width
    delta, _ = _detect(ns, t, records, covs)
    assert cov_key(["p"]) in delta.updated


def test_opaque_updated_on_access():
    ns = Namespace()
    ns["g"] = OpaqueLeaf(payload=1)
    records = build_all(ns)
    covs = group_covariables(records)
    t = TrackedNamespace(ns)
    _ = t["g"]                         # read counts as possible update
    delta, _ = _detect(ns, t, records, covs)
    assert cov_key(["g"]) in delta.updated   # conservative (Table 5 semantics)


def test_deleted_names():
    ns = Namespace()
    ns["p"] = np.zeros(4, np.float32)
    ns["q"] = np.ones(4, np.float32)
    records = build_all(ns)
    covs = group_covariables(records)
    t = TrackedNamespace(ns)
    del t["q"]
    delta, records = _detect(ns, t, records, covs)
    assert cov_key(["q"]) in delta.deleted
    assert "q" not in records
