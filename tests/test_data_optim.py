"""Data pipeline determinism/sharding + optimizer correctness."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.pipeline import DataState, TokenPipeline
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def test_pipeline_deterministic():
    p = TokenPipeline(1000, 4, 32)
    s = DataState(seed=5, step=3)
    b1 = p.batch_at(s)
    b2 = p.batch_at(DataState(seed=5, step=3))
    assert np.array_equal(b1["tokens"], b2["tokens"])
    b3 = p.batch_at(DataState(seed=5, step=4))
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_host_sharding_partitions_global_batch():
    """Two hosts' shards concatenated == the single-host global batch."""
    g = TokenPipeline(1000, 8, 16, n_hosts=1, host_id=0)
    h0 = TokenPipeline(1000, 8, 16, n_hosts=2, host_id=0)
    h1 = TokenPipeline(1000, 8, 16, n_hosts=2, host_id=1)
    s = DataState(seed=1, step=0)
    full = g.batch_at(s)["tokens"]
    part = np.concatenate([h0.batch_at(s)["tokens"],
                           h1.batch_at(s)["tokens"]])
    assert np.array_equal(full, part)


def test_pipeline_labels_are_shifted_tokens():
    p = TokenPipeline(1000, 2, 16)
    b = p.batch_at(DataState(0, 0))
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_elastic_resume():
    """Continuing from a checkpointed DataState on a different host count
    yields the same global stream."""
    s = DataState(seed=2, step=7)
    one = TokenPipeline(500, 4, 8, n_hosts=1).batch_at(s)["tokens"]
    quads = [TokenPipeline(500, 4, 8, n_hosts=4, host_id=i).batch_at(s)["tokens"]
             for i in range(4)]
    assert np.array_equal(one, np.concatenate(quads))


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": params["w"]}          # d/dw (w^2/2)
        params, opt, _ = adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_bf16_moments():
    cfg = AdamWConfig(lr=0.01, moment_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    opt = adamw_init(params, cfg)
    assert opt["mu"]["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((4, 4))}
    p2, opt2, m = adamw_update(grads, opt, params, cfg)
    assert opt2["nu"]["w"].dtype == jnp.bfloat16
    assert float(p2["w"][0, 0]) < 1.0


def test_adamw_dynamic_lr():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([1.0])}
    opt = adamw_init(params, cfg)
    p_hi, _, _ = adamw_update({"w": jnp.array([1.0])}, opt, params, cfg,
                              lr=jnp.float32(0.1))
    p_lo, _, _ = adamw_update({"w": jnp.array([1.0])}, opt, params, cfg,
                              lr=jnp.float32(0.001))
    assert float(p_hi["w"][0]) < float(p_lo["w"][0])


def test_grad_clip():
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params, cfg)
    _, _, m = adamw_update({"w": jnp.full(3, 100.0)}, opt, params, cfg)
    assert float(m["grad_norm"]) > 100.0        # reported pre-clip
