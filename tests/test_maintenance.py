"""Branch deletion, garbage collection, state diff, async straggler paths."""
import time

import numpy as np
import pytest

from repro.core import FaultInjectedStore, KishuSession, MemoryStore
from repro.core.chunkstore import DirectoryStore, SQLiteStore


def make_session(store=None):
    s = KishuSession(store or MemoryStore(), chunk_bytes=1 << 10)

    def set_val(ns, name, val):
        ns[name] = np.full(1000, float(val), np.float32)
    s.register("set_val", set_val)
    s.init_state({})
    return s


@pytest.fixture(params=["memory", "dir", "sqlite"])
def any_store(request, tmp_path):
    if request.param == "memory":
        return MemoryStore()
    if request.param == "dir":
        return DirectoryStore(str(tmp_path / "cas"))
    return SQLiteStore(str(tmp_path / "cas.db"))


def live_chunks(sess):
    out = set()
    for node in sess.graph.nodes.values():
        for man in node.manifests.values():
            if man.get("unserializable"):
                continue
            out.update(c["key"] for c in man["base"]["chunks"])
    return out


def test_diff_api():
    s = make_session()
    s.run("set_val", name="x", val=1)
    a = s.run("set_val", name="y", val=2)
    s.checkout(a)
    b = s.run("set_val", name="y", val=3)
    s.checkout(a)
    c = s.run("set_val", name="z", val=4)
    d = s.diff(b, c)
    assert "y" in d["diverged"][0] or any("y" in k for k in d["diverged"])
    assert any("z" in k for k in d["diverged"])
    assert d["identical"] >= 1          # x identical


def test_delete_branch_and_gc():
    store = MemoryStore()
    s = make_session(store)
    s.run("set_val", name="x", val=1)
    root = s.head
    # branch A (to be deleted) with unique data
    a1 = s.run("set_val", name="big_a", val=7)
    a2 = s.run("set_val", name="big_a", val=8)
    s.checkout(root)
    # branch B (kept)
    b1 = s.run("set_val", name="b", val=9)
    n_before = store.n_chunks()
    doomed = s.delete_branch(a2)
    assert a2 in doomed and a1 in doomed
    stats = s.gc()
    assert stats["chunks_dropped"] >= 1
    assert store.n_chunks() < n_before
    # surviving branch unaffected
    s.checkout(root)
    s.checkout(b1)
    assert float(s.ns["b"][0]) == 9.0


def test_gc_keeps_shared_chunks():
    store = MemoryStore()
    s = make_session(store)
    s.run("set_val", name="x", val=1)
    root = s.head
    a = s.run("set_val", name="x", val=2)   # same content later re-created
    s.checkout(root)
    b = s.run("set_val", name="x", val=2)   # identical bytes -> same chunks
    s.delete_branch(a)
    s.gc()
    s.checkout(root)
    s.checkout(b)                            # must still load fine
    assert float(s.ns["x"][0]) == 2.0


def test_gc_reclaims_dead_chunks_all_backends(any_store):
    """gc() must reclaim on every backend — including SQLite, where chunk
    enumeration historically no-oped — and must drop *exactly* the chunks
    orphaned by the branch deletion."""
    s = make_session(any_store)
    s.run("set_val", name="x", val=1)
    root = s.head
    a1 = s.run("set_val", name="big_a", val=7)
    a2 = s.run("set_val", name="big_a", val=8)
    s.checkout(root)
    b1 = s.run("set_val", name="b", val=9)

    before = set(any_store.list_chunk_keys())
    assert live_chunks(s) == before          # nothing orphaned yet
    doomed = s.delete_branch(a2)
    assert a1 in doomed and a2 in doomed
    live = live_chunks(s)                    # manifests surviving deletion
    dead = before - live
    assert dead                              # branch A had unique data

    stats = s.gc()
    after = set(any_store.list_chunk_keys())
    assert after == live                     # exactly the doomed reclaimed
    assert stats["chunks_dropped"] == len(dead)
    assert stats["bytes_freed"] > 0
    assert stats["chunks_live"] == len(live)

    s.checkout(root)                         # survivors still restore
    s.checkout(b1)
    assert float(np.asarray(s.ns["b"])[0]) == 9.0


def test_gc_noop_when_no_garbage(any_store):
    s = make_session(any_store)
    c1 = s.run("set_val", name="x", val=1)
    s.run("set_val", name="y", val=2)
    stats = s.gc()
    assert stats["chunks_dropped"] == 0 and stats["bytes_freed"] == 0
    s.checkout(c1)
    assert float(np.asarray(s.ns["x"])[0]) == 1.0


def test_delete_branch_then_gc_keeps_other_branch_loadable(any_store):
    s = make_session(any_store)
    s.run("set_val", name="x", val=1)
    root = s.head
    a = s.run("set_val", name="x", val=2)    # branch A
    s.checkout(root)
    b = s.run("set_val", name="x", val=3)    # branch B
    s.checkout(root)
    s.delete_branch(a)
    s.delete_branch(b)
    s.gc()
    c = s.run("set_val", name="x", val=4)
    s.checkout(root)
    s.checkout(c)
    assert float(np.asarray(s.ns["x"])[0]) == 4.0


def test_reload_after_delete_branch(any_store):
    """delete_branch writes tombstone meta docs; re-opening the store (new
    session / CLI) must skip them instead of crashing at graph load."""
    s = make_session(any_store)
    s.run("set_val", name="x", val=1)
    root = s.head
    a = s.run("set_val", name="x", val=2)
    s.checkout(root)
    b = s.run("set_val", name="x", val=3)
    s.checkout(root)
    doomed = s.delete_branch(a)
    s.gc()
    s.close()

    s2 = KishuSession(any_store, chunk_bytes=1 << 10)   # reload
    assert set(doomed).isdisjoint(s2.graph.nodes)
    assert b in s2.graph.nodes

    def set_val(ns, name, val):
        ns[name] = np.full(1000, float(val), np.float32)
    s2.register("set_val", set_val)
    s2.checkout(b)
    assert float(np.asarray(s2.ns["x"])[0]) == 3.0


def test_cannot_delete_current_branch():
    s = make_session()
    c = s.run("set_val", name="x", val=1)
    with pytest.raises(AssertionError):
        s.delete_branch(c)


def test_async_straggler_deadline_falls_back():
    """A host whose writes exceed the deadline leaves chunks pending; an
    immediate checkout falls back to recomputation instead of blocking."""
    inner = MemoryStore()
    slow = FaultInjectedStore(inner, write_delay=0.05)
    s = KishuSession(slow, chunk_bytes=1 << 8, async_write=True,
                     write_deadline_s=0.01)

    def set_val(ns, name, val):
        ns[name] = np.full(5000, float(val), np.float32)
    s.register("set_val", set_val)
    s.init_state({})
    c1 = s.run("set_val", name="x", val=1)
    # commit returned before all chunks landed
    c2 = s.run("set_val", name="x", val=2)
    s.checkout(c1)                           # flushes; must be correct
    assert float(s.ns["x"][0]) == 1.0
    s.close()
