"""Branch deletion, garbage collection, state diff, async straggler paths."""
import time

import numpy as np
import pytest

from repro.core import FaultInjectedStore, KishuSession, MemoryStore


def make_session(store=None):
    s = KishuSession(store or MemoryStore(), chunk_bytes=1 << 10)

    def set_val(ns, name, val):
        ns[name] = np.full(1000, float(val), np.float32)
    s.register("set_val", set_val)
    s.init_state({})
    return s


def test_diff_api():
    s = make_session()
    s.run("set_val", name="x", val=1)
    a = s.run("set_val", name="y", val=2)
    s.checkout(a)
    b = s.run("set_val", name="y", val=3)
    s.checkout(a)
    c = s.run("set_val", name="z", val=4)
    d = s.diff(b, c)
    assert "y" in d["diverged"][0] or any("y" in k for k in d["diverged"])
    assert any("z" in k for k in d["diverged"])
    assert d["identical"] >= 1          # x identical


def test_delete_branch_and_gc():
    store = MemoryStore()
    s = make_session(store)
    s.run("set_val", name="x", val=1)
    root = s.head
    # branch A (to be deleted) with unique data
    a1 = s.run("set_val", name="big_a", val=7)
    a2 = s.run("set_val", name="big_a", val=8)
    s.checkout(root)
    # branch B (kept)
    b1 = s.run("set_val", name="b", val=9)
    n_before = store.n_chunks()
    doomed = s.delete_branch(a2)
    assert a2 in doomed and a1 in doomed
    stats = s.gc()
    assert stats["chunks_dropped"] >= 1
    assert store.n_chunks() < n_before
    # surviving branch unaffected
    s.checkout(root)
    s.checkout(b1)
    assert float(s.ns["b"][0]) == 9.0


def test_gc_keeps_shared_chunks():
    store = MemoryStore()
    s = make_session(store)
    s.run("set_val", name="x", val=1)
    root = s.head
    a = s.run("set_val", name="x", val=2)   # same content later re-created
    s.checkout(root)
    b = s.run("set_val", name="x", val=2)   # identical bytes -> same chunks
    s.delete_branch(a)
    s.gc()
    s.checkout(root)
    s.checkout(b)                            # must still load fine
    assert float(s.ns["x"][0]) == 2.0


def test_cannot_delete_current_branch():
    s = make_session()
    c = s.run("set_val", name="x", val=1)
    with pytest.raises(AssertionError):
        s.delete_branch(c)


def test_async_straggler_deadline_falls_back():
    """A host whose writes exceed the deadline leaves chunks pending; an
    immediate checkout falls back to recomputation instead of blocking."""
    inner = MemoryStore()
    slow = FaultInjectedStore(inner, write_delay=0.05)
    s = KishuSession(slow, chunk_bytes=1 << 8, async_write=True,
                     write_deadline_s=0.01)

    def set_val(ns, name, val):
        ns[name] = np.full(5000, float(val), np.float32)
    s.register("set_val", set_val)
    s.init_state({})
    c1 = s.run("set_val", name="x", val=1)
    # commit returned before all chunks landed
    c2 = s.run("set_val", name="x", val=2)
    s.checkout(c1)                           # flushes; must be correct
    assert float(s.ns["x"][0]) == 1.0
    s.close()
