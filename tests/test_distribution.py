"""Distribution tests that need multiple devices — run in subprocesses with
their own XLA_FLAGS (the main test process must keep 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow    # JAX jit-heavy; fast lane: -m "not slow"


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_sharded_train_step_runs_on_8_devices():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import get_config
        from repro.models.testing import reduced
        from repro.optim.adamw import AdamWConfig
        from repro.train import step as step_lib
        from repro.sharding.rules import ShardingRules

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = reduced(get_config("qwen3-1.7b"), n_layers=2).replace(
            d_model=64, n_heads=4, n_kv_heads=4, head_dim=16)
        oc = AdamWConfig(lr=1e-3)
        rules = ShardingRules(cfg, mesh)
        state = step_lib.init_train_state(cfg, jax.random.key(0), oc)
        pshard = rules.param_shardings(state["params"])
        sshard = {"params": pshard,
                  "opt": {"mu": pshard, "nu": pshard, "count": rules.replicated()},
                  "step": rules.replicated(), "rng": rules.replicated()}
        state = jax.device_put(state, sshard)
        batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
                 "labels": jnp.zeros((4, 32), jnp.int32)}
        batch = jax.device_put(batch, rules.batch_spec(batch))
        fn = jax.jit(step_lib.make_train_step(cfg, oc, remat=True),
                     in_shardings=(sshard, rules.batch_spec(batch)),
                     out_shardings=(sshard, rules.replicated()))
        with mesh:
            state2, metrics = fn(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        print("SHARDED_OK", float(metrics["loss"]))
    """)
    assert "SHARDED_OK" in out


def test_sharded_equals_single_device():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import get_config
        from repro.models.testing import reduced
        from repro.optim.adamw import AdamWConfig
        from repro.train import step as step_lib
        from repro.sharding.rules import ShardingRules

        cfg = reduced(get_config("smollm-360m"), n_layers=2)
        oc = AdamWConfig(lr=1e-3)
        state = step_lib.init_train_state(cfg, jax.random.key(0), oc)
        batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 16), 0, 100),
                 "labels": jax.random.randint(jax.random.key(2), (4, 16), 0, 100)}
        fn = step_lib.make_train_step(cfg, oc, remat=False)
        # single-device reference
        s_ref, m_ref = fn(jax.device_put(state), batch)
        # sharded
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = ShardingRules(cfg, mesh)
        pshard = rules.param_shardings(state["params"])
        sshard = {"params": pshard,
                  "opt": {"mu": pshard, "nu": pshard, "count": rules.replicated()},
                  "step": rules.replicated(), "rng": rules.replicated()}
        with mesh:
            s_sh, m_sh = jax.jit(fn, in_shardings=(sshard, rules.batch_spec(batch)),
                                 out_shardings=(sshard, rules.replicated()))(
                jax.device_put(state, sshard), jax.device_put(batch, rules.batch_spec(batch)))
        assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-3, \
            (float(m_ref["loss"]), float(m_sh["loss"]))
        l_ref = jax.tree.leaves(s_ref["params"])[0]
        l_sh = jax.tree.leaves(s_sh["params"])[0]
        np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_sh),
                                   atol=2e-2, rtol=2e-2)
        print("EQUIV_OK")
    """)
    assert "EQUIV_OK" in out


def test_compressed_psum_numerics():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import compressed_psum, residual_init

        mesh = jax.make_mesh((8,), ("data",))
        # per-device distinct gradients, replicated layout
        def make(i):
            return {"w": jnp.full((64,), float(i + 1)),
                    "b": jnp.linspace(-1, 1, 32) * (i + 1)}
        grads = make(0)
        res = residual_init(grads)

        # emulate 8 different device grads by running shard_map over stacked
        # data: use vmap-free approach — call compressed_psum on a pytree of
        # [8, ...] arrays sharded over data, inside shard_map semantics.
        stacked = {"w": jnp.stack([make(i)["w"] for i in range(8)]),
                   "b": jnp.stack([make(i)["b"] for i in range(8)])}
        from jax.experimental.shard_map import shard_map
        def body(g):
            g = jax.tree.map(lambda x: x[0], g)    # local shard [1,...] -> [...]
            r = jax.tree.map(lambda x: jnp.zeros_like(x), g)
            def inner(gl, rl):
                gl32 = gl.astype(jnp.float32) + rl
                amax = jax.lax.pmax(jnp.max(jnp.abs(gl32)), "data")
                scale = jnp.maximum(amax, 1e-12) / 127.0
                q = jnp.clip(jnp.round(gl32 / scale), -127, 127).astype(jnp.int8)
                s = jax.lax.psum(q.astype(jnp.int32), "data")
                return (s.astype(jnp.float32) * scale / 8.0)[None]
            return jax.tree.map(inner, g, r)
        sharded = jax.device_put(
            stacked, jax.tree.map(lambda _: jax.NamedSharding(mesh, P("data")), stacked))
        with mesh:
            out = shard_map(body, mesh=mesh, in_specs=(P("data"),),
                            out_specs=P("data"), check_rep=False)(sharded)
        got = jax.tree.map(lambda x: np.asarray(x)[0], out)
        want = {k: np.mean([np.asarray(make(i)[k]) for i in range(8)], axis=0)
                for k in ("w", "b")}
        for k in ("w", "b"):
            scale = np.abs(want[k]).max() + 1e-9
            err = np.abs(got[k] - want[k]).max() / scale
            assert err < 0.02, (k, err)
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


def test_dryrun_machinery_small_mesh():
    """The dry-run builder works end-to-end on a small mesh with a reduced
    arch (fast proxy for the 512-device run, which runs separately)."""
    out = run_sub("""
        import jax, numpy as np
        from repro.launch import dryrun
        from repro.models.config import get_config, register
        from repro.models.testing import reduced

        base = get_config("qwen3-1.7b")
        small = reduced(base, n_layers=2).replace(name="tiny-test")
        register(small)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cell = dryrun.build_cell("tiny-test", "train_4k", mesh)
        lowered = cell["jfn"].lower(*cell["args"])
        compiled = lowered.compile()
        hlo = compiled.as_text()
        coll = dryrun.collective_bytes(hlo)
        assert coll["total"] > 0, "expected collectives in sharded train step"
        print("DRYRUN_SMALL_OK", coll["total"])
    """)
    assert "DRYRUN_SMALL_OK" in out


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes, _shape_bytes
    hlo = """
  %ar = bf16[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = f32[512]{0} all-gather(%y), dimensions={0}
  %rs = (f32[64]{0}, f32[64]{0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = u8[100]{0} collective-permute-start(%z)
  %cpd = u8[100]{0} collective-permute-done(%cp)
  %other = f32[2,2]{1,0} add(%p, %q)
"""
    c = collective_bytes(hlo)
    assert c["all-reduce"] == 128 * 256 * 2
    assert c["all-gather"] == 512 * 4
    assert c["reduce-scatter"] == 2 * 64 * 4
    assert c["collective-permute"] == 100       # start counted, done skipped
    assert c["n_all-reduce"] == 1
