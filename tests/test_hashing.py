"""Hash-spec tests: np/jnp agreement, sensitivity, length folding."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hashing as H


@pytest.mark.parametrize("size", [0, 1, 3, 4, 100, 4096, 4097, 1 << 16])
def test_np_jnp_agree(size):
    rng = np.random.default_rng(size)
    buf = rng.integers(0, 256, size, dtype=np.uint8).tobytes()
    cb = 4096
    h_np = H.chunk_hashes_np(buf, cb)
    if size == 0:
        assert h_np.size == 0
        return
    words, nbytes = H.words_view(buf, cb)
    h_j = H.combine_u64(np.asarray(
        H.chunk_hashes_jnp(jnp.asarray(words), jnp.asarray(nbytes))))
    assert np.array_equal(h_np, h_j)


@settings(max_examples=40, deadline=None)
@given(st.binary(min_size=1, max_size=5000),
       st.integers(min_value=0, max_value=4999))
def test_single_byte_flip_changes_hash(buf, pos):
    pos = pos % len(buf)
    cb = 1024
    h1 = H.chunk_hashes_np(buf, cb)
    b2 = bytearray(buf)
    b2[pos] ^= 0x5A
    h2 = H.chunk_hashes_np(bytes(b2), cb)
    chunk = pos // cb
    assert h1[chunk] != h2[chunk]
    # all other chunks unaffected
    mask = np.ones(len(h1), bool)
    mask[chunk] = False
    assert np.array_equal(h1[mask], h2[mask])


def test_length_folding_prevents_pad_collisions():
    for n in (1, 5, 100, 4095):
        a = H.chunk_hashes_np(b"\x00" * n, 4096)
        b = H.chunk_hashes_np(b"\x00" * (n + 1), 4096)
        assert a[0] != b[0]


def test_order_sensitivity():
    a = H.chunk_hashes_np(b"\x01\x00\x00\x00\x02\x00\x00\x00", 4096)
    b = H.chunk_hashes_np(b"\x02\x00\x00\x00\x01\x00\x00\x00", 4096)
    assert a[0] != b[0]


@settings(max_examples=25, deadline=None)
@given(st.binary(min_size=0, max_size=10_000))
def test_deterministic(buf):
    assert np.array_equal(H.chunk_hashes_np(buf, 2048),
                          H.chunk_hashes_np(bytes(buf), 2048))


def test_device_hash_matches_numpy(monkeypatch):
    """The delta pipeline's device-side detection hashes (Pallas kernel /
    jnp fallback) must agree bit-for-bit with the host hasher, or delta
    plans would silently diverge between CPU and accelerator sessions."""
    monkeypatch.setenv("KISHU_DEVICE_HASH", "1")
    x = jnp.arange(5000, dtype=jnp.float32) * 0.5
    h = H.chunk_hashes_device(x, 1 << 12)
    if h is None:
        pytest.skip("no device hash backend available")
    ref = H.chunk_hashes_np(np.asarray(x).tobytes(), 1 << 12)
    assert np.array_equal(np.asarray(h), ref)


def test_device_hash_disabled_by_env(monkeypatch):
    monkeypatch.setenv("KISHU_DEVICE_HASH", "0")
    assert H.chunk_hashes_device(jnp.ones(16, jnp.float32), 1 << 12) is None


def test_hashes_hex_roundtrip():
    h = np.array([0, 1, 0xdeadbeef], np.uint64)
    hx = H.hashes_hex(h)
    assert hx == ["0000000000000000", "0000000000000001",
                  "00000000deadbeef"]
    assert H.hashes_hex(None) == []
