"""Cross-backend store-composition properties.

Any composition of the store zoo — plain backends, compressed, fault-free
wrappers, sharded rings, replica sets, tiers, and nestings thereof — must
expose identical ChunkStore semantics:

  P1  put/get round-trips logical bytes exactly (batched and single ops)
  P2  keys are content-addressed and codec-agnostic: key == blake2b(logical)
      no matter which composition stored the chunk
  P3  list_chunk_keys enumerates exactly the live keys (no dupes across
      shards/replicas)
  P4  delete_chunks removes everywhere; CAS dedup still holds afterwards

The hypothesis run fuzzes blob sets over in-memory compositions; the
deterministic run covers every composition (including disk backends) with a
fixed corpus, so tier-1 exercises the matrix even without hypothesis.
"""
import pytest
from _hypothesis_compat import HAVE_HYPOTHESIS, HealthCheck, given, settings, st

from repro.core import (CompressedStore, MemoryStore, ReplicatedStore,
                        ShardedStore, TieredStore)
from repro.core.chunkstore import DirectoryStore, SQLiteStore, chunk_key


def _mem_composition(kind):
    if kind == "sharded":
        return ShardedStore([MemoryStore() for _ in range(3)])
    if kind == "replicated":
        return ReplicatedStore([MemoryStore() for _ in range(2)])
    if kind == "tiered":
        return TieredStore(MemoryStore(), hot_bytes=1 << 12)
    if kind == "compressed":
        return CompressedStore(MemoryStore(), "zlib")
    if kind == "sharded_rep":
        return ShardedStore([
            ReplicatedStore([MemoryStore(), MemoryStore()]),
            ReplicatedStore([MemoryStore(), MemoryStore()])])
    if kind == "compressed_sharded_tier":
        return CompressedStore(ShardedStore([
            TieredStore(MemoryStore(), hot_bytes=1 << 12),
            TieredStore(MemoryStore(), hot_bytes=1 << 12)]), "zlib")
    raise AssertionError(kind)


MEM_KINDS = ["sharded", "replicated", "tiered", "compressed", "sharded_rep",
             "compressed_sharded_tier"]


def _disk_composition(kind, tmp_path):
    if kind == "sharded_dirs":
        return ShardedStore([DirectoryStore(str(tmp_path / f"s{i}"))
                             for i in range(3)])
    if kind == "rep_sqlite":
        return ReplicatedStore([SQLiteStore(str(tmp_path / f"r{i}.db"))
                                for i in range(2)])
    if kind == "tier_sqlite":
        return TieredStore(SQLiteStore(str(tmp_path / "cold.db")),
                           hot_bytes=1 << 12)
    if kind == "codec_shard_mixed":
        return CompressedStore(ShardedStore([
            DirectoryStore(str(tmp_path / "m0")),
            SQLiteStore(str(tmp_path / "m1.db"))]), "zlib")
    raise AssertionError(kind)


DISK_KINDS = ["sharded_dirs", "rep_sqlite", "tier_sqlite",
              "codec_shard_mixed"]


def _check_invariants(store, blobs):
    pairs = {chunk_key(d): d for d in blobs}
    items = list(pairs.items())
    written = store.put_chunks(items)
    assert 0 <= written <= len(items)
    # P1/P2: round-trip + content addressing, batched and single
    assert store.get_chunks(list(pairs)) == pairs
    for k, d in items[:3]:
        assert store.get_chunk(k) == d
        assert chunk_key(store.get_chunk(k)) == k
        assert store.has_chunk(k)
    # P3: enumeration is exact and dupe-free
    listed = store.list_chunk_keys()
    assert sorted(listed) == sorted(pairs)
    # P4: CAS dedup — rewriting everything adds nothing
    assert store.put_chunks(items) == 0
    assert sorted(store.list_chunk_keys()) == sorted(pairs)
    # delete a prefix; the rest survives
    doomed = list(pairs)[:len(pairs) // 2]
    store.delete_chunks(doomed)
    for k in doomed:
        assert not store.has_chunk(k)
    keep = {k: d for k, d in pairs.items() if k not in doomed}
    assert store.get_chunks(list(keep)) == keep
    assert sorted(store.list_chunk_keys()) == sorted(keep)


CORPUS = [b"", b"x", b"hello world" * 40, b"\x00" * 3000,
          bytes(range(256)) * 8, b"KZC1 looks like a frame" * 3,
          b"A" * 5000]


@pytest.mark.parametrize("kind", MEM_KINDS)
def test_composition_invariants_fixed_corpus(kind):
    _check_invariants(_mem_composition(kind), CORPUS)


@pytest.mark.parametrize("kind", DISK_KINDS)
def test_disk_composition_invariants_fixed_corpus(kind, tmp_path):
    _check_invariants(_disk_composition(kind, tmp_path), CORPUS)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=25, deadline=None,
          suppress_health_check=list(HealthCheck) if HAVE_HYPOTHESIS else [])
@given(kind=st.sampled_from(MEM_KINDS),
       blobs=st.lists(st.binary(min_size=0, max_size=2048), min_size=1,
                      max_size=12))
def test_composition_invariants_fuzzed(kind, blobs):
    _check_invariants(_mem_composition(kind), blobs)
